"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU) — shape/dtype
sweeps per kernel, plus hypothesis property tests for the DP kernel."""
import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.cckp_dp.cckp_dp import cckp_model_dp
from repro.kernels.cckp_dp.ref import cckp_model_dp_ref
from repro.kernels.decode_attention.decode_attention import \
    decode_attention_fwd
from repro.kernels.decode_attention.ops import decode_attention, \
    ring_validity
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.rglru_scan import rglru_scan_fwd
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_sequential_ref

NEG = -1e30


# ------------------------------------------------------------ flash attn --
@pytest.mark.parametrize("mask_kind,window", [("causal", 0), ("none", 0),
                                              ("window", 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,sk,d,bq,bk", [
    (64, 64, 32, 16, 16),
    (48, 48, 16, 16, 16),      # non-multiple seq (padding path)
    (32, 96, 64, 32, 32),      # cross-ish Sk > Sq
])
def test_flash_attention_sweep(mask_kind, window, dtype, sq, sk, d, bq, bk):
    if mask_kind in ("causal", "window") and sq != sk:
        pytest.skip("self-attention masks assume square positions")
    key = jax.random.key(0)
    BH = 4
    q = jax.random.normal(jax.random.fold_in(key, 0), (BH, sq, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH, sk, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH, sk, d), dtype)
    out = flash_attention_fwd(q, k, v, mask_kind=mask_kind, window=window,
                              bq=bq, bk=bk, interpret=True)
    ref = attention_ref(q, k, v, mask_kind=mask_kind, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_gqa_group_mapping():
    key = jax.random.key(1)
    B, KH, G, S, D = 2, 2, 3, 32, 16
    q = jax.random.normal(jax.random.fold_in(key, 0), (B * KH * G, S, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B * KH, S, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B * KH, S, D))
    out = flash_attention_fwd(q, k, v, mask_kind="causal", group=G,
                              bq=16, bk=16, interpret=True)
    kr = jnp.repeat(k, G, axis=0)
    vr = jnp.repeat(v, G, axis=0)
    ref = attention_ref(q, kr, vr, mask_kind="causal")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ----------------------------------------------------------- decode attn --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sk,g,d,bk", [(128, 4, 32, 32), (100, 6, 16, 32),
                                       (64, 1, 64, 16)])
def test_decode_attention_sweep(dtype, sk, g, d, bk):
    key = jax.random.key(2)
    BKH = 3
    q = jax.random.normal(jax.random.fold_in(key, 0), (BKH, g, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (BKH, sk, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (BKH, sk, d), dtype)
    valid = (jax.random.uniform(jax.random.fold_in(key, 3), (BKH, sk))
             > 0.3).astype(jnp.int32)
    valid = valid.at[:, 0].set(1)      # at least one valid slot
    out = decode_attention_fwd(q, k, v, valid, bk=bk, interpret=True)
    ref = decode_attention_ref(q, k, v, valid)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_decode_attention_matches_model_decode_path():
    """Kernel ring-buffer semantics vs layers.attn_decode math."""
    B, W, KH, G, D = 2, 16, 2, 2, 8
    H = KH * G
    key = jax.random.key(3)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, 1, H, D))
    ck = jax.random.normal(jax.random.fold_in(key, 1), (B, W, KH, D))
    cv = jax.random.normal(jax.random.fold_in(key, 2), (B, W, KH, D))
    for index, window in [(5, 0), (20, 0), (20, 7)]:
        out = decode_attention(q, ck, cv, jnp.asarray(index), window=window)
        # reference: mask from ring validity + grouped dense attention
        ok = ring_validity(W, jnp.asarray(index), window)
        kr = jnp.repeat(ck, G, axis=2).transpose(0, 2, 1, 3).reshape(
            B * H, W, D)
        vr = jnp.repeat(cv, G, axis=2).transpose(0, 2, 1, 3).reshape(
            B * H, W, D)
        qf = q[:, 0].transpose(0, 1, 2).reshape(B * H, 1, D)
        s = jnp.einsum("bqd,bkd->bqk", qf, kr) * D ** -0.5
        s = jnp.where(ok[None, None, :] != 0, s, NEG)
        p = jax.nn.softmax(s, axis=-1)
        ref = jnp.einsum("bqk,bkd->bqd", p, vr).reshape(B, H, 1, D
                                                        ).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


# ------------------------------------------------------------------- ssd --
@pytest.mark.parametrize("s,h,p,n,chunk", [(32, 2, 8, 4, 8), (40, 3, 4, 8, 16),
                                           (16, 1, 16, 16, 16)])
def test_ssd_kernel_vs_sequential(s, h, p, n, chunk):
    key = jax.random.key(4)
    B = 2
    x = jax.random.normal(jax.random.fold_in(key, 0), (B, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)))
    B_ = jax.random.normal(jax.random.fold_in(key, 3), (B, s, n))
    C_ = jax.random.normal(jax.random.fold_in(key, 4), (B, s, n))
    y, state = ssd_scan(x, dt, A, B_, C_, chunk)
    yr, stater = ssd_sequential_ref(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state),
                               np.asarray(stater.transpose(0, 1, 2, 3)),
                               atol=1e-3, rtol=1e-3)


def test_ssd_jnp_chunked_matches_sequential():
    from repro.models.layers import ssd_scan_chunked
    key = jax.random.key(5)
    B, s, h, p, n = 2, 24, 2, 4, 8
    x = jax.random.normal(jax.random.fold_in(key, 0), (B, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)))
    B_ = jax.random.normal(jax.random.fold_in(key, 3), (B, s, n))
    C_ = jax.random.normal(jax.random.fold_in(key, 4), (B, s, n))
    y, state = ssd_scan_chunked(x, dt, A, B_, C_, 8)
    yr, stater = ssd_sequential_ref(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(stater),
                               atol=1e-3, rtol=1e-3)


# ----------------------------------------------------------------- rglru --
@pytest.mark.parametrize("s,w,bs,bw", [(32, 16, 8, 8), (50, 24, 16, 16),
                                       (16, 8, 16, 8)])
def test_rglru_kernel_vs_ref(s, w, bs, bw):
    key = jax.random.key(6)
    B = 2
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 0),
                                         (B, s, w)))
    b = jax.random.normal(jax.random.fold_in(key, 1), (B, s, w))
    y = rglru_scan_fwd(a, b, bs=bs, bw=bw, interpret=True)
    yr = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)


# --------------------------------------------------------------- cckp dp --
@pytest.mark.parametrize("T,K,p,n_steps", [(20, 6, 3, 6), (50, 10, 7, 11),
                                           (10, 4, 0, 4)])
def test_cckp_kernel_vs_ref(T, K, p, n_steps):
    rng = np.random.default_rng(0)
    y0 = np.full((T + 1, K + 1), NEG, np.float32)
    y0[:, 0] = 0.0
    y0[5:, 1] = rng.uniform(0, 1)      # some pre-existing partial solutions
    y = jnp.asarray(y0)
    a = jnp.asarray(0.37, jnp.float32)
    out, bq = cckp_model_dp(y, a, p=p, n_steps=n_steps, interpret=True)
    outr, bqr = cckp_model_dp_ref(y, 0.37, p=p, n_steps=n_steps)
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(bq), np.asarray(bqr))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(1, 3),
       n_l=st.integers(1, 5), T_int=st.integers(1, 25))
def test_cckp_pallas_impl_end_to_end(seed, m, n_l, T_int):
    """solve_cckp(impl='pallas') is bit-identical to the jnp DP."""
    from repro.core.amdp import solve_cckp
    rng = np.random.default_rng(seed)
    p = rng.integers(1, 8, size=m).astype(np.int64)
    a = rng.uniform(0.1, 1.0, size=m)
    c1, v1 = solve_cckp(p, a, T_int, n_l, impl="jnp")
    c2, v2 = solve_cckp(p, a, T_int, n_l, impl="pallas")
    if c1 is None:
        assert c2 is None
    else:
        assert v1 == pytest.approx(v2, abs=1e-5)
        np.testing.assert_array_equal(c1, c2)


# ---------------------------------------------- model-level pallas path --
def test_model_attention_pallas_path_matches_dense():
    """cfg.attn_impl='pallas' routes layers.attention through the kernel
    (interpret mode on CPU) and must match the dense path."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import forward, init_params

    cfg_d = dataclasses.replace(get_smoke_config("internlm2_20b"),
                                attn_impl="dense")
    cfg_p = dataclasses.replace(cfg_d, attn_impl="pallas")
    key = jax.random.key(7)
    params = init_params(cfg_d, key)
    batch = {"tokens": jax.random.randint(jax.random.fold_in(key, 1),
                                          (2, 16), 0, cfg_d.vocab_size)}
    h_d = forward(params, batch, cfg_d)
    h_p = forward(params, batch, cfg_p)
    np.testing.assert_allclose(np.asarray(h_d, np.float32),
                               np.asarray(h_p, np.float32), atol=6e-2)


# ---------------------------------------------------------- simplex pivot --
@pytest.mark.parametrize("B,R1,C1", [(4, 5, 9), (8, 15, 41), (1, 3, 4)])
def test_simplex_pivot_kernel_vs_ref(B, R1, C1):
    from repro.kernels.simplex_pivot.ref import pivot_update_ref
    from repro.kernels.simplex_pivot.simplex_pivot import simplex_pivot
    rng = np.random.default_rng(B * 100 + C1)
    tabs = rng.normal(size=(B, R1, C1))
    # keep pivots well away from zero so ref/kernel divide identically
    r = rng.integers(0, R1 - 1, size=B)
    j = rng.integers(0, C1 - 1, size=B)
    tabs[np.arange(B), r, j] += np.sign(tabs[np.arange(B), r, j]) + 1.0
    mask = rng.uniform(size=B) < 0.7
    tabs = jnp.asarray(tabs, jnp.float32)
    got = simplex_pivot(tabs, jnp.asarray(r), jnp.asarray(j),
                        jnp.asarray(mask), interpret=True)
    ref = pivot_update_ref(tabs, jnp.asarray(r), jnp.asarray(j),
                           jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)
    # masked lanes must pass through untouched
    np.testing.assert_array_equal(np.asarray(got)[~mask],
                                  np.asarray(tabs)[~mask])


def test_simplex_pivot_ref_is_a_simplex_pivot():
    """The reference update must do an actual Gauss-Jordan pivot: pivot
    column becomes a unit vector, pivot row is normalized."""
    from repro.kernels.simplex_pivot.ref import pivot_update_ref
    rng = np.random.default_rng(0)
    tabs = jnp.asarray(rng.normal(size=(2, 4, 6)) + 2.0)
    r = jnp.array([1, 2])
    j = jnp.array([0, 3])
    out = np.asarray(pivot_update_ref(tabs, r, j,
                                      jnp.ones(2, dtype=bool)))
    for b in range(2):
        col = out[b, :, int(j[b])]
        expect = np.zeros(4)
        expect[int(r[b])] = 1.0
        np.testing.assert_allclose(col, expect, atol=1e-12)


def _reduced_state(B, R, C0, seed):
    """A valid cold revised-simplex state: identity factor, xB = b > 0,
    every row basic on its VIRTUAL artificial (labels >= C0)."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(B, R, C0))
    xB = rng.uniform(0.5, 2.0, size=(B, R))
    c_phase = np.zeros((B, C0))        # phase 1: artificials cost art_cost
    Binv = np.broadcast_to(np.eye(R), (B, R, R)).copy()
    basis = np.broadcast_to(C0 + np.arange(R, dtype=np.int32), (B, R)).copy()
    with enable_x64():
        return tuple(jnp.asarray(x) for x in (A, c_phase, Binv, xB)) + (
            jnp.asarray(basis, jnp.int32),)


@pytest.mark.parametrize("B,R,C0", [(4, 5, 9), (8, 11, 27), (1, 3, 4)])
def test_reduced_pivot_kernel_vs_ref(B, R, C0):
    """The fused reduced-factor pivot kernel must replay the jnp oracle:
    all pivot DECISIONS (basis labels, has_enter/unbounded/degenerate
    flags) exactly, and the updated [Binv | xB] factor to within a few
    ulps — the ref prices via einsum (dot-general) while the kernel uses
    an elementwise multiply-reduce, so the accumulation order can differ
    at shapes where XLA picks different lowerings.  (At the fleet LP
    shape the two are measured bit-identical; `tests/test_lp.py` pins
    that.)  Masked lanes must pass through untouched, bit for bit."""
    from repro.kernels.simplex_pivot.ops import reduced_pivot
    from repro.kernels.simplex_pivot.ref import reduced_pivot_ref
    with enable_x64():
        A, c_phase, Binv, xB, basis = _reduced_state(B, R, C0, B * 10 + C0)
        rng = np.random.default_rng(1)
        use_bland = jnp.asarray(rng.uniform(size=B) < 0.3)
        may_pivot = jnp.ones(B, bool)
        lane_ok = jnp.asarray(rng.uniform(size=B) < 0.8)
        args = (A, c_phase, Binv, xB, basis, use_bland, may_pivot, lane_ok)
        got = reduced_pivot(*args, art_cost=1.0, tol=1e-7)
        ref = reduced_pivot_ref(*args, art_cost=1.0, tol=1e-7)
        for g, r in zip(got[:2], ref[:2]):       # Binv', xB': ulp-close
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       rtol=1e-13, atol=1e-15)
        for g, r in zip(got[2:], ref[2:]):       # basis + flags: exact
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
        ok = np.asarray(lane_ok)
        np.testing.assert_array_equal(np.asarray(got[0])[~ok],
                                      np.asarray(Binv)[~ok])
        np.testing.assert_array_equal(np.asarray(got[2])[~ok],
                                      np.asarray(basis)[~ok])


def test_reduced_pivot_ref_maintains_basis_inverse():
    """After a pivot the updated factor must still be the inverse of the
    basis matrix the updated labels describe (virtual label C0+k <-> e_k,
    real label j <-> column A[:, j]) — i.e. the eta update is a genuine
    product-form basis-inverse update, not just a tableau transform."""
    from repro.kernels.simplex_pivot.ref import reduced_pivot_ref
    with enable_x64():
        B, R, C0 = 6, 5, 12
        A, c_phase, Binv, xB, basis = _reduced_state(B, R, C0, 3)
        on = jnp.ones(B, bool)
        for _ in range(3):                    # a few successive pivots
            Binv, xB, basis, has_enter, unbounded, _deg = reduced_pivot_ref(
                A, c_phase, Binv, xB, basis, ~on, on, on,
                art_cost=1.0, tol=1e-7)
        An, Bn, bn = (np.asarray(A), np.asarray(Binv),
                      np.asarray(basis))
        for b in range(B):
            Bmat = np.stack(
                [An[b, :, l] if l < C0 else np.eye(R)[l - C0]
                 for l in bn[b]], axis=1)
            np.testing.assert_allclose(Bn[b] @ Bmat, np.eye(R), atol=1e-9)
