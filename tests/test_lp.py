"""JAX/NumPy simplex vs scipy.linprog (oracle) — unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from scipy.optimize import linprog

from repro.core import solve_lp, OPTIMAL, INFEASIBLE


def _random_lp(seed, n=10, mc=5, feasible=True):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=n)
    A_ub = rng.uniform(0, 1, size=(mc, n))
    b_ub = rng.uniform(1, 3, size=mc)
    A_eq = np.ones((1, n))
    b_eq = np.array([1.0 if feasible else 100.0])  # sum x = 100 with x<=~3 cap
    return c, A_ub, b_ub, A_eq, b_eq


@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("seed", range(8))
def test_matches_scipy_on_random_feasible(backend, seed):
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(seed)
    ours = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend)
    ref = linprog(c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq,
                  bounds=(0, None))
    assert ours.status == OPTIMAL and ref.status == 0
    assert ours.fun == pytest.approx(ref.fun, abs=1e-4)
    # solution feasibility
    x = ours.x
    assert np.all(x >= -1e-6)
    assert np.all(A_ub @ x <= b_ub + 1e-5)
    assert np.allclose(A_eq @ x, b_eq, atol=1e-5)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_detects_infeasible(backend):
    # sum x = 100 while every x bounded by b_ub/Aub rows ~ 3
    n = 6
    c = np.ones(n)
    A_ub = np.eye(n)
    b_ub = np.full(n, 3.0)
    A_eq = np.ones((1, n))
    b_eq = np.array([100.0])
    ours = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend)
    assert ours.status == INFEASIBLE


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_equality_only(backend):
    # min x0 + 2 x1 s.t. x0 + x1 = 1
    res = solve_lp(np.array([1.0, 2.0]), A_eq=np.array([[1.0, 1.0]]),
                   b_eq=np.array([1.0]), backend=backend)
    assert res.status == OPTIMAL
    assert res.fun == pytest.approx(1.0, abs=1e-6)
    assert res.x[0] == pytest.approx(1.0, abs=1e-5)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_inequality_only(backend):
    # max x (min -x) s.t. x <= 5
    res = solve_lp(np.array([-1.0]), A_ub=np.array([[1.0]]),
                   b_ub=np.array([5.0]), backend=backend)
    assert res.status == OPTIMAL
    assert res.fun == pytest.approx(-5.0, abs=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(3, 14),
       mc=st.integers(1, 6))
def test_property_matches_scipy(seed, n, mc):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=n)
    A_ub = rng.uniform(0, 1, size=(mc, n))
    b_ub = rng.uniform(0.5, 3, size=mc)
    A_eq = np.ones((1, n))
    b_eq = np.array([1.0])
    ours = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend="numpy")
    ref = linprog(c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq,
                  bounds=(0, None))
    if ref.status == 0:
        assert ours.status == OPTIMAL
        assert ours.fun == pytest.approx(ref.fun, abs=1e-6)
    elif ref.status == 2:
        assert ours.status == INFEASIBLE


def test_basic_solution_has_basis():
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(0)
    res = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend="numpy")
    # basis has one entry per row: mc + n_eq rows
    assert len(res.basis) == A_ub.shape[0] + A_eq.shape[0]


# ---------------------------------------------------------------------------
# degenerate pivoting: Bland's-rule fallback (anti-cycling)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_degenerate_beale_reaches_optimum(backend):
    """Beale's classic cycling LP: fully degenerate at the origin — the
    Dantzig rule with naive tie-breaks cycles forever on it.  The solver
    (index tie-break + Bland fallback after K degenerate pivots) must reach
    the optimum -0.05 within the iteration budget."""
    c = np.array([-0.75, 150.0, -0.02, 6.0])
    A_ub = np.array([[0.25, -60.0, -0.04, 9.0],
                     [0.5, -90.0, -0.02, 3.0],
                     [0.0, 0.0, 1.0, 0.0]])
    b_ub = np.array([0.0, 0.0, 1.0])
    res = solve_lp(c, A_ub, b_ub, backend=backend, maxiter=100)
    assert res.status == OPTIMAL
    assert res.fun == pytest.approx(-0.05, abs=1e-6)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("seed", range(4))
def test_pure_bland_rule_matches_scipy(backend, seed):
    """bland_after=0 runs the whole solve under Bland's entering rule — it
    must find the same optimum (slower, but guaranteed cycle-free)."""
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(seed)
    res = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend,
                   bland_after=0)
    ref = linprog(c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq,
                  bounds=(0, None))
    assert res.status == OPTIMAL and ref.status == 0
    assert res.fun == pytest.approx(ref.fun, abs=1e-4)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_degenerate_origin_lp(backend):
    """Every pivot from the all-slack basis is degenerate (b = 0 rows):
    the degeneracy counter must engage Bland and still terminate at the
    (unique, origin) optimum."""
    rng = np.random.default_rng(7)
    n, mc = 5, 4
    c = np.abs(rng.normal(size=n))          # minimize over x >= 0: opt = 0
    A_ub = rng.normal(size=(mc, n))
    b_ub = np.zeros(mc)
    res = solve_lp(c, A_ub, b_ub, backend=backend, maxiter=200)
    assert res.status == OPTIMAL
    assert res.fun == pytest.approx(0.0, abs=1e-8)


# ---------------------------------------------------------------------------
# status propagation: iteration limit / unbounded must never be silent
# ---------------------------------------------------------------------------
from repro.core import UNBOUNDED                      # noqa: E402
from repro.core.lp import ITERATION_LIMIT             # noqa: E402


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_tiny_maxiter_reports_iteration_limit(backend):
    """A maxiter-capped solve must say so — including when phase 1 is the
    phase that got capped (its status used to be discarded and the capped
    tableau could be reported as 'optimal' or 'infeasible')."""
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(3)
    res = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend, maxiter=1)
    assert res.status == ITERATION_LIMIT
    assert not res.success


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_unbounded_reported(backend):
    # min -x s.t. -x <= 0 (x >= 0): unbounded below
    res = solve_lp(np.array([-1.0]), A_ub=np.array([[-1.0]]),
                   b_ub=np.array([0.0]), backend=backend)
    assert res.status == UNBOUNDED


# ---------------------------------------------------------------------------
# warm starts: revised-simplex start from a previous basis
# ---------------------------------------------------------------------------
def _batch_lp(seed=0, nb=5, n=8, mc=3):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=(nb, n))
    A_ub = rng.uniform(0, 1, size=(nb, mc, n))
    b_ub = rng.uniform(1, 3, size=(nb, mc))
    A_eq = np.ones((nb, 1, n))
    b_eq = np.ones((nb, 1))
    return c, A_ub, b_ub, A_eq, b_eq


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_warm_start_identical_resolve_is_zero_pivots(backend):
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(1)
    cold = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend)
    warm = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend,
                    warm_basis=cold.basis)
    assert warm.warm and warm.status == OPTIMAL and warm.niter == 0
    assert warm.fun == pytest.approx(cold.fun, abs=1e-6)
    np.testing.assert_array_equal(np.sort(warm.basis), np.sort(cold.basis))


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_warm_start_perturbed_instance(backend):
    """The fleet scenario: next period's instance differs slightly; the old
    basis remains (near-)optimal and the warm solve matches a cold one."""
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(2)
    cold0 = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend)
    rng = np.random.default_rng(5)
    A2 = A_ub * (1.0 + 0.05 * rng.normal(size=A_ub.shape))
    warm = solve_lp(c, A2, b_ub, A_eq, b_eq, backend=backend,
                    warm_basis=cold0.basis)
    cold = solve_lp(c, A2, b_ub, A_eq, b_eq, backend=backend)
    assert warm.status == OPTIMAL
    assert warm.fun == pytest.approx(cold.fun, abs=1e-6)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_warm_start_rejected_basis_falls_back_cold(backend):
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(4)
    cold = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend)
    bad = np.full_like(cold.basis, -1)
    warm = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend,
                    warm_basis=bad)
    assert not warm.warm                   # rejected -> cold path ran
    assert warm.status == OPTIMAL
    assert warm.fun == pytest.approx(cold.fun, abs=1e-9)


def test_solve_lp_batch_warm_matches_cold():
    from repro.core import solve_lp_batch
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(0)
    cold = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    assert not cold.warm.any()
    warm = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq,
                          warm_basis=cold.basis)
    assert warm.warm.all() and (warm.niter == 0).all()
    np.testing.assert_allclose(warm.fun, cold.fun, atol=1e-9)
    np.testing.assert_allclose(warm.x, cold.x, atol=1e-9)


def test_solve_lp_batch_warm_mixed_rejections():
    """Lanes with stale (-1) bases are re-solved cold and still correct;
    accepted lanes keep the warm fast path."""
    from repro.core import solve_lp_batch
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(1)
    cold = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    wb = cold.basis.copy()
    wb[::2] = -1                           # every other lane: no basis
    warm = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, warm_basis=wb)
    assert (~warm.warm[::2]).all() and warm.warm[1::2].all()
    np.testing.assert_allclose(warm.fun, cold.fun, atol=1e-9)
    assert (warm.status == OPTIMAL).all()


def test_solve_lp_batch_warm_shape_guard():
    from repro.core import solve_lp_batch
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(2)
    with pytest.raises(ValueError, match="warm_basis"):
        solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq,
                       warm_basis=np.zeros((2, 2), dtype=np.int64))


def test_solve_lp_batch_warm_pallas_impl_matches_jnp():
    """impl='pallas' routes the batched pivot through the simplex_pivot
    kernel (interpret mode on CPU) — bit-identical trajectory to jnp."""
    from repro.core import solve_lp_batch
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(3)
    cold = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    rng = np.random.default_rng(9)
    A2 = A_ub * (1.0 + 0.1 * rng.normal(size=A_ub.shape))
    ref = solve_lp_batch(c, A2, b_ub, A_eq, b_eq, warm_basis=cold.basis,
                         impl="jnp")
    got = solve_lp_batch(c, A2, b_ub, A_eq, b_eq, warm_basis=cold.basis,
                         impl="pallas")
    np.testing.assert_array_equal(got.status, ref.status)
    np.testing.assert_array_equal(got.niter, ref.niter)
    np.testing.assert_array_equal(got.basis, ref.basis)
    np.testing.assert_allclose(got.x, ref.x, atol=1e-12)


# ---------------------------------------------------------------------------
# simplex_batch_core: the traced warm-or-cold engine path vs the host
# solve_lp_batch dispatch (accepted-warm + cold-fallback), lane for lane
# ---------------------------------------------------------------------------
def _run_core(c, A_ub, b_ub, A_eq, b_eq, basis0, lane_mask=None):
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.lp import (_bucket_maxiter, _canonicalize_batch,
                               simplex_batch_core)
    A, b, cf, nv, _ = _canonicalize_batch(c, A_ub, b_ub, A_eq, b_eq)
    maxiter = _bucket_maxiter(50 * (A.shape[1] + 2))
    with enable_x64():
        out = jax.jit(
            lambda A_, b_, c_: simplex_batch_core(
                A_, b_, c_,
                None if basis0 is None else jnp.asarray(basis0),
                nv=nv, maxiter=maxiter,
                lane_mask=None if lane_mask is None
                else jnp.asarray(lane_mask)))(
            jnp.asarray(A), jnp.asarray(b), jnp.asarray(cf))
    return [np.asarray(o) for o in out]      # x, fun, status, niter, basis, ok


@pytest.mark.parametrize("seed", range(3))
def test_simplex_batch_core_cold_bitwise_matches_solve_lp_batch(seed):
    from repro.core import solve_lp_batch
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(seed, nb=6)
    ref = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    for basis0 in (None, np.full_like(ref.basis, -1)):
        x, fun, status, niter, basis, ok = _run_core(
            c, A_ub, b_ub, A_eq, b_eq, basis0)
        assert not ok.any()
        np.testing.assert_array_equal(status, ref.status)
        np.testing.assert_array_equal(niter, ref.niter)
        np.testing.assert_array_equal(basis, ref.basis)
        np.testing.assert_array_equal(x, ref.x)          # bitwise
        np.testing.assert_array_equal(fun, ref.fun)


def test_simplex_batch_core_warm_and_rejected_match_host_dispatch():
    """Accepted lanes follow `_warm_batch_jit` (shared `_warm_init` /
    `_two_phase_virtual`); rejected/-1 lanes run cold IN the same call and
    must still match the host's subset re-solve bitwise."""
    from repro.core import solve_lp_batch
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(7, nb=6)
    cold = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    rng = np.random.default_rng(3)
    c2 = c + 0.05 * rng.normal(size=c.shape)   # perturbed next period
    wb = cold.basis.copy()
    wb[::2] = -1                               # stale every other lane
    ref = solve_lp_batch(c2, A_ub, b_ub, A_eq, b_eq, warm_basis=wb)
    x, fun, status, niter, basis, ok = _run_core(
        c2, A_ub, b_ub, A_eq, b_eq, wb)
    np.testing.assert_array_equal(ok, np.asarray(ref.warm))
    np.testing.assert_array_equal(status, ref.status)
    np.testing.assert_array_equal(niter, ref.niter)
    np.testing.assert_array_equal(basis, ref.basis)
    np.testing.assert_array_equal(x, ref.x)
    np.testing.assert_array_equal(fun, ref.fun)


def test_simplex_batch_core_infeasible_lane_status():
    from repro.core import solve_lp_batch
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(5, nb=4)
    b_eq = b_eq.copy()
    b_eq[1] = 100.0                            # sum x = 100 with x <= ~3 cap
    ref = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    assert ref.status[1] == INFEASIBLE
    x, fun, status, niter, basis, ok = _run_core(
        c, A_ub, b_ub, A_eq, b_eq, None)
    np.testing.assert_array_equal(status, ref.status)
    np.testing.assert_array_equal(x, ref.x)


def test_simplex_batch_core_lane_mask_zeroes_masked_lanes():
    """Masked-out lanes spend zero pivots and active lanes are untouched
    by their presence (the engine's backpressure masking)."""
    from repro.core import solve_lp_batch
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(2, nb=6)
    ref = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    lane_mask = np.array([True, False, True, False, True, False])
    x, fun, status, niter, basis, ok = _run_core(
        c, A_ub, b_ub, A_eq, b_eq, None, lane_mask=lane_mask)
    np.testing.assert_array_equal(x[lane_mask], ref.x[lane_mask])
    np.testing.assert_array_equal(niter[lane_mask], ref.niter[lane_mask])
    assert (niter[~lane_mask] == 0).all()


# ---------------------------------------------------------------------------
# method="revised": the reduced-tableau revised simplex vs the dense tableau
# ---------------------------------------------------------------------------
def _run_core_m(c, A_ub, b_ub, A_eq, b_eq, basis0, method, impl="jnp",
                lane_mask=None, maxiter=None):
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.lp import (_bucket_maxiter, _canonicalize_batch,
                               simplex_batch_core)
    A, b, cf, nv, _ = _canonicalize_batch(c, A_ub, b_ub, A_eq, b_eq)
    if maxiter is None:
        maxiter = _bucket_maxiter(50 * (A.shape[1] + 2))
    with enable_x64():
        out = simplex_batch_core(
            jnp.asarray(A), jnp.asarray(b), jnp.asarray(cf),
            None if basis0 is None else jnp.asarray(basis0),
            nv=nv, maxiter=maxiter, method=method, impl=impl,
            lane_mask=None if lane_mask is None else jnp.asarray(lane_mask))
    return [np.asarray(o) for o in out]


def _fleet_lp(B, seed=0):
    from repro.core import InstanceBatch, random_instance
    from repro.core.amr2 import build_lp_arrays_batch
    batch = InstanceBatch.stack(
        [random_instance(8, 2, T=1.2, seed=seed + s) for s in range(B)])
    return build_lp_arrays_batch(batch)


@pytest.mark.parametrize("seed", range(3))
def test_revised_cold_matches_tableau_small(seed):
    """Cold parity contract: statuses exact; OPTIMAL lanes agree on x and
    objective to fp noise.  (Pivot SEQUENCES can differ between the two
    representations on degenerate floating-point Dantzig ties — observed
    only on INFEASIBLE lanes of random batches, whose x/fun are
    meaningless — so niter/basis are deliberately not pinned cold.)"""
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(seed, nb=6)
    t = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, None, "tableau")
    r = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, None, "revised")
    np.testing.assert_array_equal(r[2], t[2])          # status, every lane
    opt = t[2] == OPTIMAL
    np.testing.assert_allclose(r[0][opt], t[0][opt], atol=1e-12)
    np.testing.assert_allclose(r[1][opt], t[1][opt], atol=1e-12)


@pytest.mark.parametrize("B", [64, 256])
def test_revised_fleet_parity(B):
    """The ISSUE's 64/256-device pins on real fleet LPs: cold statuses
    exact + OPTIMAL-lane optima to <= 1e-12; warm restart from the
    tableau's own optimal bases accepts/rejects identically, and every
    ACCEPTED lane is pivot-for-pivot exact (0 iterations, same basis,
    bit-identical x)."""
    c, A_ub, b_ub, A_eq, b_eq = _fleet_lp(B)
    t = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, None, "tableau")
    r = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, None, "revised")
    np.testing.assert_array_equal(r[2], t[2])
    opt = t[2] == OPTIMAL
    assert opt.sum() > B // 2                    # the pin is not vacuous
    np.testing.assert_allclose(r[0][opt], t[0][opt], atol=1e-12)
    np.testing.assert_allclose(r[1][opt], t[1][opt], atol=1e-12)

    tw = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, t[4], "tableau")
    rw = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, t[4], "revised")
    np.testing.assert_array_equal(rw[5], tw[5])  # same accept/reject set
    ok = tw[5]
    assert ok.sum() > B // 2
    assert (rw[3][ok] == 0).all()                # optimal basis: 0 pivots
    np.testing.assert_array_equal(rw[4][ok], tw[4][ok])
    np.testing.assert_array_equal(rw[0][ok], tw[0][ok])   # bitwise
    np.testing.assert_allclose(rw[1][ok], tw[1][ok], atol=1e-12)


def test_revised_pallas_impl_bit_identical():
    """The fused reduced-pivot kernel (interpret mode on CPU) replays the
    jnp reference trajectory bit for bit across a whole two-phase solve."""
    for seed in (0, 7):
        c, A_ub, b_ub, A_eq, b_eq = _batch_lp(seed, nb=6)
        ref = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, None, "revised",
                          impl="jnp")
        got = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, None, "revised",
                          impl="pallas")
        np.testing.assert_array_equal(got[2], ref[2])
        np.testing.assert_array_equal(got[3], ref[3])
        np.testing.assert_array_equal(got[4], ref[4])
        np.testing.assert_array_equal(got[0], ref[0])
        np.testing.assert_array_equal(got[1], ref[1])


def test_revised_infeasible_lane_status():
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(5, nb=4)
    b_eq = b_eq.copy()
    b_eq[1] = 100.0                            # sum x = 100 with x <= ~3 cap
    t = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, None, "tableau")
    r = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, None, "revised")
    assert r[2][1] == INFEASIBLE
    np.testing.assert_array_equal(r[2], t[2])


def test_revised_lane_mask_zeroes_masked_lanes():
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(2, nb=6)
    full = _run_core_m(c, A_ub, b_ub, A_eq, b_eq, None, "revised")
    lane_mask = np.array([True, False, True, False, True, False])
    x, fun, status, niter, basis, ok = _run_core_m(
        c, A_ub, b_ub, A_eq, b_eq, None, "revised", lane_mask=lane_mask)
    np.testing.assert_array_equal(x[lane_mask], full[0][lane_mask])
    np.testing.assert_array_equal(niter[lane_mask], full[3][lane_mask])
    assert (niter[~lane_mask] == 0).all()


def test_simplex_batch_core_unknown_method_raises():
    from repro.core.lp import simplex_batch_core
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(0, nb=2)
    from repro.core.lp import _canonicalize_batch
    A, b, cf, nv, _ = _canonicalize_batch(c, A_ub, b_ub, A_eq, b_eq)
    with pytest.raises(ValueError, match="method"):
        simplex_batch_core(A, b, cf, None, nv=nv, maxiter=8,
                           method="dense")


def test_solve_lp_batch_method_revised_host_dispatch():
    """`solve_lp_batch(method="revised")` resolves warm AND rejected lanes
    in one jitted call (no pow2-padded subset re-solve) and agrees with
    the tableau dispatch on status, acceptance, and optima."""
    from repro.core import solve_lp_batch
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(1)
    ref = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    got = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, method="revised")
    np.testing.assert_array_equal(got.status, ref.status)
    opt = np.asarray(ref.status) == OPTIMAL
    np.testing.assert_allclose(got.x[opt], ref.x[opt], atol=1e-12)
    np.testing.assert_allclose(got.fun[opt], ref.fun[opt], atol=1e-12)

    wb = np.asarray(ref.basis).copy()
    wb[::2] = -1                               # stale every other lane
    wref = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, warm_basis=wb)
    wgot = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, warm_basis=wb,
                          method="revised")
    np.testing.assert_array_equal(wgot.warm, wref.warm)
    np.testing.assert_array_equal(wgot.status, wref.status)
    accepted = np.asarray(wref.warm)
    np.testing.assert_array_equal(wgot.basis[accepted], wref.basis[accepted])
    np.testing.assert_allclose(wgot.x[accepted], wref.x[accepted],
                               atol=1e-12)

    with pytest.raises(ValueError, match="method"):
        solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, method="etas")


# ---------------------------------------------------------------------------
# explicit maxiter= caps the TWO-PHASE TOTAL (regression: each phase used
# to spend the full budget, so niter could reach 2x the requested cap)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_explicit_maxiter_caps_two_phase_total(backend):
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(3)
    full = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend)
    assert full.status == OPTIMAL and full.niter > 4
    for cap in (1, 3, full.niter - 1):
        res = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend,
                       maxiter=cap)
        assert res.niter <= cap, \
            f"maxiter={cap} but {res.niter} iterations ran"
    # a budget of exactly the cold pivot count still certifies optimality
    # (the cap check runs AFTER the optimality check on both backends)
    exact = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend,
                     maxiter=full.niter)
    assert exact.status == OPTIMAL and exact.niter == full.niter


@pytest.mark.parametrize("method", ["tableau", "revised"])
def test_batched_explicit_maxiter_caps_two_phase_total(method):
    from repro.core import solve_lp_batch
    c, A_ub, b_ub, A_eq, b_eq = _batch_lp(4)
    res = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq, maxiter=3,
                         method=method)
    assert (np.asarray(res.niter) <= 3).all()
    assert (np.asarray(res.status) == 1).any()   # ITERATION_LIMIT surfaced
