"""JAX/NumPy simplex vs scipy.linprog (oracle) — unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from scipy.optimize import linprog

from repro.core import solve_lp, OPTIMAL, INFEASIBLE


def _random_lp(seed, n=10, mc=5, feasible=True):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=n)
    A_ub = rng.uniform(0, 1, size=(mc, n))
    b_ub = rng.uniform(1, 3, size=mc)
    A_eq = np.ones((1, n))
    b_eq = np.array([1.0 if feasible else 100.0])  # sum x = 100 with x<=~3 cap
    return c, A_ub, b_ub, A_eq, b_eq


@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("seed", range(8))
def test_matches_scipy_on_random_feasible(backend, seed):
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(seed)
    ours = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend)
    ref = linprog(c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq,
                  bounds=(0, None))
    assert ours.status == OPTIMAL and ref.status == 0
    assert ours.fun == pytest.approx(ref.fun, abs=1e-4)
    # solution feasibility
    x = ours.x
    assert np.all(x >= -1e-6)
    assert np.all(A_ub @ x <= b_ub + 1e-5)
    assert np.allclose(A_eq @ x, b_eq, atol=1e-5)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_detects_infeasible(backend):
    # sum x = 100 while every x bounded by b_ub/Aub rows ~ 3
    n = 6
    c = np.ones(n)
    A_ub = np.eye(n)
    b_ub = np.full(n, 3.0)
    A_eq = np.ones((1, n))
    b_eq = np.array([100.0])
    ours = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend=backend)
    assert ours.status == INFEASIBLE


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_equality_only(backend):
    # min x0 + 2 x1 s.t. x0 + x1 = 1
    res = solve_lp(np.array([1.0, 2.0]), A_eq=np.array([[1.0, 1.0]]),
                   b_eq=np.array([1.0]), backend=backend)
    assert res.status == OPTIMAL
    assert res.fun == pytest.approx(1.0, abs=1e-6)
    assert res.x[0] == pytest.approx(1.0, abs=1e-5)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_inequality_only(backend):
    # max x (min -x) s.t. x <= 5
    res = solve_lp(np.array([-1.0]), A_ub=np.array([[1.0]]),
                   b_ub=np.array([5.0]), backend=backend)
    assert res.status == OPTIMAL
    assert res.fun == pytest.approx(-5.0, abs=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(3, 14),
       mc=st.integers(1, 6))
def test_property_matches_scipy(seed, n, mc):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=n)
    A_ub = rng.uniform(0, 1, size=(mc, n))
    b_ub = rng.uniform(0.5, 3, size=mc)
    A_eq = np.ones((1, n))
    b_eq = np.array([1.0])
    ours = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend="numpy")
    ref = linprog(c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq,
                  bounds=(0, None))
    if ref.status == 0:
        assert ours.status == OPTIMAL
        assert ours.fun == pytest.approx(ref.fun, abs=1e-6)
    elif ref.status == 2:
        assert ours.status == INFEASIBLE


def test_basic_solution_has_basis():
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(0)
    res = solve_lp(c, A_ub, b_ub, A_eq, b_eq, backend="numpy")
    # basis has one entry per row: mc + n_eq rows
    assert len(res.basis) == A_ub.shape[0] + A_eq.shape[0]
