"""Multi-cell mobility subsystem (`repro.core.mobility` + engine v2):
segmented per-cell admission vs the sequential oracles, routing geometry,
handover warm-basis/belief migration, the S=1 / infinite-radius bitwise
reduction pin, and the chaos ES-audit satellite."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.api import engine as E
from repro.core.faults import FaultModel
from repro.core.mobility import (MobilityModel, admit_mask_cells_np,
                                 admit_mask_segmented, route_cells,
                                 validate_mobility)
from repro.serving import FleetConfig, FleetEngine


def _config(n_devices=8, *, n_servers=6, horizon=14, seed=0, rate=9.0):
    return FleetConfig(n_devices=n_devices, T=1.2, n_servers=n_servers,
                       policy="amr2", backend="jax", rate=rate,
                       batch_max=8, horizon=horizon, seed=seed,
                       straggler_frac=0.25, outage_frac=0.1)


def _three_cells(D, horizon, seed=3, radius=9.0):
    rng = np.random.default_rng(seed)
    cxy = np.array([[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]])
    trace = (rng.normal(scale=4.0, size=(horizon, D, 2))
             + cxy[rng.integers(0, 3, D)])
    return MobilityModel.make(
        cell_xy=cxy, trace=trace, cell_rate=np.array([1.0, 0.8, 1.2]),
        radius=radius, link_alpha=0.5)


# ---------------------------------------------------------------------------
# the acceptance pin: S=1 + infinite radius reduces to today's engine BITWISE
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["replay", "walk"])
def test_s1_infinite_radius_reduces_bitwise(mode):
    """One cell at the origin with an infinite coverage radius and unit
    link rate is geometrically inert: every device is always covered,
    the link factor is exactly 1.0, and admission stays on the S=1
    sequential scan — so arming mobility must not move a single bit of
    the trajectory (metrics AND state leaves)."""
    periods = 12
    cfg = _config(8, horizon=periods + 2)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    s_off, m_off = E.rollout(E.init_state(params), params, periods)
    trace = np.zeros((periods + 2, 8, 2))
    mob = MobilityModel.make(cell_xy=np.zeros((1, 2)), trace=trace)
    armed = params.with_mobility(mob, mode=mode, mobility_seed=7)
    s_on, m_on = E.rollout(E.init_state(armed), armed, periods)
    for f in E._METRIC_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(m_off, f)),
                                      np.asarray(getattr(m_on, f)), f)
    for f in ("key", "p_ed", "pending", "head", "warm_basis", "n_updates",
              "p_es_belief"):
        np.testing.assert_array_equal(np.asarray(getattr(s_off, f)),
                                      np.asarray(getattr(s_on, f)), f)
    assert int(np.asarray(m_on.n_handover).sum()) == 0


def test_step_sequence_equals_rollout_with_mobility():
    periods = 8
    cfg = _config(8, horizon=periods + 2)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    p3 = params.with_mobility(_three_cells(8, periods + 2),
                              routing="min_time")
    s_roll, m = E.rollout(E.init_state(p3), p3, periods)
    s = E.init_state(p3)
    for _ in range(periods):
        s, _ = E.step(s, p3)
    for f in E._STATE_FIELDS:
        for a, b in zip(jax.tree.leaves(getattr(s, f)),
                        jax.tree.leaves(getattr(s_roll, f))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), f)
    assert int(np.asarray(m.n_handover).sum()) > 0


# ---------------------------------------------------------------------------
# segmented per-cell admission vs the sequential oracles
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 3))
def test_segmented_admission_matches_per_cell_oracle(seed, n_cells, k):
    """`admit_mask_segmented` (sort/cumsum, no sequential pass) admits
    exactly the set the per-cell sequential first-fit oracle admits, and
    books the same per-cell load totals."""
    rng = np.random.default_rng(seed)
    D = int(rng.integers(1, 40))
    demands = np.where(rng.random(D) < 0.3, 0.0,
                       rng.uniform(0.0, 1.5, D)).astype(np.float64)
    cell = rng.integers(-1, n_cells, D).astype(np.int32)
    T = 1.2
    adm, loads = admit_mask_segmented(
        jnp.asarray(demands), jnp.asarray(cell), T, n_cells, k)
    adm_np, loads_np = admit_mask_cells_np(demands, cell, T, n_cells, k)
    np.testing.assert_array_equal(np.asarray(adm), adm_np)
    # per-server placement may permute on equal-demand ties; the admitted
    # LOAD multiset per cell is the invariant
    np.testing.assert_allclose(np.sort(np.asarray(loads), axis=1),
                               np.sort(loads_np, axis=1), atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3))
def test_segmented_equals_global_scan_at_one_cell(seed, k):
    """With a single cell the segmented formulation must reproduce the
    global sequential scan (`admit_mask_jnp`, the bitwise-pinned S=1
    oracle) exactly."""
    rng = np.random.default_rng(seed)
    D = int(rng.integers(1, 48))
    demands = np.where(rng.random(D) < 0.3, 0.0,
                       rng.uniform(0.0, 1.5, D)).astype(np.float64)
    T = 1.2
    adm_seg, loads_seg = admit_mask_segmented(
        jnp.asarray(demands), jnp.zeros(D, jnp.int32), T, 1, k)
    adm_glob, loads_glob = E.admit_mask_jnp(jnp.asarray(demands), T, k)
    np.testing.assert_array_equal(np.asarray(adm_seg),
                                  np.asarray(adm_glob))
    np.testing.assert_allclose(np.sort(np.asarray(loads_seg).ravel()),
                               np.sort(np.asarray(loads_glob)), atol=1e-12)


# ---------------------------------------------------------------------------
# routing geometry
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["nearest", "min_time"]))
def test_routing_respects_coverage_radius(seed, routing):
    """A device is assigned a cell iff SOME cell is within the coverage
    radius, and the assigned cell is always one of the covering cells."""
    rng = np.random.default_rng(seed)
    D, S = int(rng.integers(1, 24)), int(rng.integers(1, 5))
    cxy = rng.uniform(-10, 10, (S, 2))
    pos = rng.uniform(-15, 15, (D, 2))
    radius = float(rng.uniform(1.0, 12.0))
    mob = MobilityModel.make(cell_xy=cxy, trace=pos[None],
                             cell_rate=rng.uniform(0.5, 2.0, S),
                             radius=radius, link_alpha=0.5)
    cell, covered, lf = (np.asarray(a) for a in route_cells(
        jnp.asarray(pos), mob, jnp.asarray(rng.uniform(0, 1, S)), routing))
    dist = np.linalg.norm(pos[:, None] - cxy[None], axis=2)
    in_range = dist <= radius
    np.testing.assert_array_equal(covered, in_range.any(axis=1))
    assert ((cell >= 0) == covered).all()
    ok = covered.nonzero()[0]
    assert in_range[ok, cell[ok]].all()       # never routed out of range
    np.testing.assert_array_equal(lf[~covered], 1.0)
    if routing == "nearest":
        np.testing.assert_allclose(
            dist[ok, cell[ok]],
            np.where(in_range[ok], dist[ok], np.inf).min(axis=1))


# ---------------------------------------------------------------------------
# handover: warm-basis + belief migration
# ---------------------------------------------------------------------------
class _Captured(Exception):
    pass


def _capture_step_inputs(monkeypatch, state, params):
    captured = {}

    def spy(belief, warm, *a, **k):
        captured["warm"] = np.asarray(warm)
        captured["es_belief"] = np.asarray(k["es_belief"])
        raise _Captured

    monkeypatch.setattr(E, "_period_impl", spy)
    from jax.experimental import enable_x64
    with enable_x64(), pytest.raises(_Captured):
        E._step_impl(state, params)
    return captured


def test_handover_masks_warm_basis_both_directions(monkeypatch):
    """A mid-horizon cell switch (either direction) cold-starts exactly
    the switching devices' warm rows and migrates their ES beliefs back
    to the nominal table — composing with, not replacing, the outage-flip
    staleness rule."""
    D, periods = 6, 4
    cfg = _config(D, n_servers=2, horizon=periods)
    params = E.EngineParams.from_config(cfg, horizon=periods)
    outage = np.zeros((D, params.outage.shape[1]), bool)
    outage[3, 1] = True                      # device 3: outage flip at t=1
    params = dataclasses.replace(params, outage=outage)
    # 2 cells; place devices so their t=1 routing is known
    cxy = np.array([[0.0, 0.0], [10.0, 0.0]])
    trace = np.zeros((periods, D, 2))
    trace[:, 1] = [10.0, 0.0]                # device 1 lives at cell 1
    trace[1, 0] = [10.0, 0.0]                # device 0: cell 0 -> cell 1
    trace[0, 1] = [10.0, 0.0]
    trace[1, 1] = [0.0, 0.0]                 # device 1: cell 1 -> cell 0
    mob = MobilityModel.make(cell_xy=cxy, trace=trace, radius=50.0)
    params = params.with_mobility(mob)
    wb = np.tile(np.arange(params.n_basis_rows, dtype=np.int32), (D, 1))
    belief = np.asarray(params.p_es) * 3.0   # inflated everywhere
    state = dataclasses.replace(
        E.init_state(params), period=np.int32(1), warm_basis=wb,
        cell=np.where(np.arange(D) == 1, 1, 0).astype(np.int32),
        p_es_belief=belief)
    got = _capture_step_inputs(monkeypatch, state, params)
    # devices 0 (0->1), 1 (1->0) switched; device 3 had an outage flip
    assert (got["warm"][0] == -1).all() and (got["warm"][1] == -1).all()
    assert (got["warm"][3] == -1).all()
    np.testing.assert_array_equal(got["warm"][[2, 4, 5]], wb[[2, 4, 5]])
    # belief migration: switched rows reset to nominal, others keep EMA
    np.testing.assert_array_equal(got["es_belief"][[0, 1]],
                                  np.asarray(params.p_es)[[0, 1]])
    np.testing.assert_array_equal(got["es_belief"][[2, 3, 4, 5]],
                                  belief[[2, 3, 4, 5]])


def test_no_handover_mask_at_period_zero(monkeypatch):
    """t=0 'switches' from the init sentinel are not handovers: the warm
    basis (all cold anyway at start, but pinned here with a live one)
    must pass through untouched."""
    D = 4
    cfg = _config(D, n_servers=2, horizon=4)
    params = E.EngineParams.from_config(cfg, horizon=4)
    params = dataclasses.replace(
        params, outage=np.zeros((D, params.outage.shape[1]), bool))
    mob = MobilityModel.make(cell_xy=np.array([[0.0, 0.0], [10.0, 0.0]]),
                             trace=np.zeros((4, D, 2)), radius=50.0)
    params = params.with_mobility(mob)
    wb = np.tile(np.arange(params.n_basis_rows, dtype=np.int32), (D, 1))
    state = dataclasses.replace(E.init_state(params), warm_basis=wb)
    got = _capture_step_inputs(monkeypatch, state, params)
    np.testing.assert_array_equal(got["warm"], wb)


# ---------------------------------------------------------------------------
# geometry validation (satellite: clear errors, not downstream NaNs)
# ---------------------------------------------------------------------------
def test_validation_rejects_bad_geometry():
    D, S = 4, 2
    good = dict(cell_xy=np.zeros((S, 2)), trace=np.zeros((3, D, 2)),
                cell_rate=np.ones(S), radius=5.0)

    def check(msg, **overrides):
        kw = {**good, **overrides}
        mob = MobilityModel(
            cell_xy=np.asarray(kw["cell_xy"]),
            cell_rate=np.asarray(kw["cell_rate"]),
            radius=np.asarray(kw["radius"]),
            link_alpha=np.float64(kw.get("link_alpha", 0.0)),
            walk_sigma=np.float64(kw.get("walk_sigma", 0.0)),
            trace=np.asarray(kw["trace"]))
        with pytest.raises(ValueError, match=msg):
            validate_mobility(mob, n_devices=D, n_servers=S,
                              mode=kw.get("mode", "replay"),
                              routing=kw.get("routing", "nearest"))

    check("float64", cell_xy=np.zeros((S, 2), np.float32))
    check("float64", trace=np.zeros((3, D, 2), np.float32))
    check("strictly positive", cell_rate=np.array([1.0, 0.0]))
    check("strictly positive", cell_rate=np.array([1.0, -2.0]))
    check("cell_rate", cell_rate=np.ones(S + 1))
    check("trace", trace=np.zeros((3, D + 1, 2)))
    check("cell_xy", cell_xy=np.zeros((S, 3)))
    check("radius", radius=0.0)
    check("divisible", cell_xy=np.zeros((3, 2)), cell_rate=np.ones(3))
    with pytest.raises(ValueError, match="mode"):
        validate_mobility(MobilityModel.none(), n_devices=D, n_servers=S,
                          mode="teleport", routing="nearest")
    with pytest.raises(ValueError, match="routing"):
        validate_mobility(MobilityModel.none(), n_devices=D, n_servers=S,
                          mode="replay", routing="random")


def test_from_fleet_and_with_mobility_validate():
    cfg = _config(4, n_servers=2, horizon=4)
    params = E.EngineParams.from_config(cfg, horizon=4)
    bad = MobilityModel(cell_xy=np.zeros((2, 2), np.float32),
                        cell_rate=np.ones(2), radius=np.float64(5.0),
                        link_alpha=np.float64(0.0),
                        walk_sigma=np.float64(0.0),
                        trace=np.zeros((3, 4, 2)))
    with pytest.raises(ValueError, match="float64"):
        params.with_mobility(bad)
    with pytest.raises(ValueError, match="divisible"):
        params.with_mobility(_three_cells(4, 4))   # 2 servers, 3 cells


def test_fleet_engine_rejects_armed_mobility():
    cfg = dataclasses.replace(
        _config(4, n_servers=2, horizon=4),
        mobility=MobilityModel.make(cell_xy=np.zeros((1, 2)),
                                    trace=np.zeros((4, 4, 2))))
    with pytest.raises(ValueError, match="pure-functional engine"):
        FleetEngine.from_config(cfg)


# ---------------------------------------------------------------------------
# satellite 1: chaos ladder -> ES-latency EMA audit
# ---------------------------------------------------------------------------
def test_chaos_off_and_armed_null_keep_es_belief_inert():
    """Chaos off (and armed with a null FaultModel) the ES audit never
    fires: p_es_belief stays == params.p_es and the shared metric fields
    are bitwise-identical to the pre-audit engine."""
    periods = 10
    cfg = _config(8, n_servers=2, horizon=periods + 2)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    s_off, m_off = E.rollout(E.init_state(params), params, periods)
    armed = dataclasses.replace(params, chaos=True)   # null model, armed
    s_null, m_null = E.rollout(E.init_state(armed), armed, periods)
    np.testing.assert_array_equal(np.asarray(s_off.p_es_belief),
                                  np.asarray(params.p_es))
    for f in E._METRIC_FIELDS:
        if f == "realized_makespan":
            continue            # priced == realized under null faults
        np.testing.assert_array_equal(np.asarray(getattr(m_off, f)),
                                      np.asarray(getattr(m_null, f)), f)
    assert int(np.asarray(m_null.n_es_audit_updates).sum()) == 0
    np.testing.assert_array_equal(np.asarray(s_null.p_es_belief),
                                  np.asarray(params.p_es))


def test_chaos_hot_inflates_es_belief_and_host_parity():
    """Link-degrade faults blow realized ES walls past the priced demand:
    the audit must fire, inflate beliefs monotonically, and the host
    `FleetEngine` delegation must thread the SAME belief trajectory
    (stats bitwise-equal to the rollout)."""
    periods = 10
    fm = FaultModel.make(link_degrade_prob=0.6, link_degrade_mag=3.0,
                         loss_rate=0.1)
    cfg = dataclasses.replace(_config(8, n_servers=2, horizon=periods + 2),
                              faults=fm, fault_seed=3)
    params = E.EngineParams.from_config(cfg, horizon=periods + 2)
    state, m = E.rollout(E.init_state(params), params, periods)
    n_upd = int(np.asarray(m.n_es_audit_updates).sum())
    assert n_upd > 0
    belief = np.asarray(state.p_es_belief)
    assert (belief >= np.asarray(params.p_es) - 1e-15).all()
    assert (belief > np.asarray(params.p_es)).any()
    # host delegation parity (threads _v2_es_belief through _period_jit)
    eng = FleetEngine.from_config(cfg)
    stats = eng.run(periods)
    for i, s in enumerate(stats):
        assert s.n_es_audit_updates == \
            int(np.asarray(m.n_es_audit_updates)[i]), i
        assert s.total_accuracy == \
            float(np.asarray(m.total_accuracy)[i]), i
        assert s.realized_makespan == \
            float(np.asarray(m.realized_makespan)[i]), i
    np.testing.assert_array_equal(eng._v2_es_belief, belief)
