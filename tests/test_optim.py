"""Optimizers: AdamW + Adafactor descend on a quadratic; Adafactor's state
is genuinely factored (memory claim)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.optim.adafactor import adafactor_init, adafactor_update


def _quad_problem(key):
    target = jax.random.normal(key, (16, 8))
    params = {"w": jnp.zeros((16, 8)), "b": jnp.zeros((8,))}

    def loss(p):
        return jnp.mean((p["w"] + p["b"] - target) ** 2)
    return params, loss


def test_adamw_descends():
    params, loss = _quad_problem(jax.random.key(0))
    opt = adamw_init(params)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(g, opt, params, lr=0.1, weight_decay=0.0)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_descends_and_is_factored():
    params, loss = _quad_problem(jax.random.key(1))
    opt = adafactor_init(params)
    assert opt.vr["w"].shape == (16,)       # factored: row stats only
    assert opt.vc["w"].shape == (8,)
    assert opt.vr["b"].shape == (8,)        # vectors keep full v
    l0 = float(loss(params))
    for _ in range(80):
        g = jax.grad(loss)(params)
        params, opt = adafactor_update(g, opt, params, lr=0.3)
    assert float(loss(params)) < 0.1 * l0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(1))) < 1e-3 * 0.2
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(100))) <= 1e-3 * 0.11  # min_ratio floor
