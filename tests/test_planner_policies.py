"""Planner policy-selection table and executor ES-failure fallback."""
import numpy as np
import pytest

from repro.core import identical_instance, paper_instance
from repro.serving import TierProfile, execute, plan, replan_without_es


def _hetero(n=12, T=2.0, seed=0):
    inst = paper_instance(n, T=T, seed=seed)
    assert not inst.is_identical()
    return inst


def test_auto_picks_amdp_on_identical_jobs():
    inst = identical_instance(10, 2, T=1.0, seed=0)
    p = plan(inst, policy="auto")
    assert p.policy == "amdp"
    assert p.schedule.solver == "amdp"


def test_auto_picks_amr2_on_heterogeneous_jobs():
    p = plan(_hetero(), policy="auto")
    assert p.policy == "amr2"
    assert p.schedule.solver == "amr2"


def test_amdp_request_falls_back_to_amr2_on_heterogeneous():
    p = plan(_hetero(), policy="amdp")
    assert p.policy == "amr2"


def test_explicit_policies_are_honored():
    inst = _hetero()
    for policy, solver in (("greedy", "greedy_rra"), ("dual", "dual")):
        p = plan(inst, policy=policy)
        assert p.policy == policy
        assert p.schedule.solver == solver


def test_invalid_policy_raises():
    with pytest.raises(ValueError):
        plan(_hetero(), policy="simulated-annealing")


def test_plan_partitions_all_jobs():
    inst = _hetero(n=16)
    p = plan(inst)
    ids = np.sort(np.concatenate(list(p.per_model.values())))
    np.testing.assert_array_equal(ids, np.arange(16))


# ---------------------------------------------------------------------------
# executor: ES outage bounces offloaded jobs back onto the ED ladder
# ---------------------------------------------------------------------------
def _applies(m=2):
    calls = {"ed": [], "es": []}

    def make_ed(i):
        def f(jobs):
            calls["ed"].append((i, len(jobs)))
            return [0.0] * len(jobs)
        return f

    def es(jobs):
        calls["es"].append(len(jobs))
        return [1.0] * len(jobs)

    return [make_ed(i) for i in range(m)], es, calls


def test_es_fail_bounced_jobs_run_on_ed_within_budget():
    prof = TierProfile(
        name="t", p_ed=np.array([[0.01, 0.04]]), p_es=np.array([0.35]),
        acc=np.array([0.4, 0.56, 0.77]), classes=[64])
    inst = prof.instance(np.full(12, 64), T=1.0)
    p = plan(inst)
    es_ids = p.per_model[inst.m]
    assert len(es_ids) > 0                      # the plan offloads some jobs

    apply_ed, apply_es, calls = _applies()
    rep = execute(p, apply_ed, apply_es, list(range(12)), es_fail=True)
    assert rep.replanned
    assert calls["es"] == []                    # the ES was never touched
    assert sorted(rep.results) == list(range(12))
    assert rep.es_wall == 0.0
    ed_jobs_run = sum(k for _, k in calls["ed"])
    assert ed_jobs_run == 12                    # every job ran on the ladder

    # the fallback plan for the bounced subset stays within the T budget on
    # the ED tier (the paper's m-model special case is solved exactly)
    sub = inst.__class__(p_ed=inst.p_ed[es_ids], p_es=inst.p_es[es_ids],
                         acc=inst.acc, T=inst.T)
    fb = replan_without_es(sub)
    assert (fb.schedule.assignment < inst.m).all()
    assert fb.schedule.ed_makespan <= inst.T + 1e-9
