"""Unit coverage for the straggler audit (`serving/runtime.py`): threshold
behaviour, the EMA update math, and the no-update-on-replan path."""
import numpy as np
import pytest

from repro.serving import ServingRuntime, TierProfile, audit_profile, plan
from repro.serving.executor import ExecutionReport


def _profile():
    return TierProfile(
        name="t", p_ed=np.array([[0.01, 0.04]]), p_es=np.array([0.35]),
        acc=np.array([0.4, 0.56, 0.77]), classes=[64])


def _runtime(**kw):
    apply_ed = [lambda jobs: [0.0] * len(jobs)] * 2
    apply_es = lambda jobs: [0.0] * len(jobs)
    return ServingRuntime(_profile(), apply_ed, apply_es, T=0.5, **kw)


def _report(ed_wall, replanned=False):
    return ExecutionReport(predicted_makespan=0.0, ed_wall=ed_wall,
                           es_wall=0.0, results={}, replanned=replanned)


def _ed_plan(rt, n=8):
    p = plan(rt.profile.instance(np.full(n, 64), rt.T))
    assert p.schedule.ed_makespan > 0
    return p


def test_audit_below_threshold_keeps_profile():
    rt = _runtime(straggler_threshold=1.5)
    p = _ed_plan(rt)
    before = rt.profile.p_ed.copy()
    updated = rt._audit(p, _report(p.schedule.ed_makespan * 1.2),
                        np.full(8, 64))
    assert not updated
    np.testing.assert_array_equal(rt.profile.p_ed, before)


def test_audit_above_threshold_applies_ema_math():
    ema = 0.5
    rt = _runtime(straggler_threshold=1.5, ema=ema)
    p = _ed_plan(rt)
    before = rt.profile.p_ed.copy()
    ratio = 3.0
    updated = rt._audit(p, _report(p.schedule.ed_makespan * ratio),
                        np.full(8, 64))
    assert updated
    np.testing.assert_allclose(
        rt.profile.p_ed, before * ((1 - ema) + ema * ratio), rtol=1e-9)


def test_audit_skips_replanned_periods():
    rt = _runtime(straggler_threshold=1.5)
    p = _ed_plan(rt)
    before = rt.profile.p_ed.copy()
    # 10x drift would normally trigger, but the period was replanned
    updated = rt._audit(p, _report(p.schedule.ed_makespan * 10.0,
                                   replanned=True), np.full(8, 64))
    assert not updated
    np.testing.assert_array_equal(rt.profile.p_ed, before)


def test_audit_profile_zero_prediction_is_noop():
    prof = _profile()
    out, updated = audit_profile(prof, 0.0, 99.0, threshold=1.5, ema=0.5)
    assert not updated and out is prof


def test_audit_profile_does_not_mutate_input():
    prof = _profile()
    before = prof.p_ed.copy()
    out, updated = audit_profile(prof, 1.0, 4.0, threshold=1.5, ema=0.25)
    assert updated
    np.testing.assert_array_equal(prof.p_ed, before)
    np.testing.assert_allclose(out.p_ed, before * (0.75 + 0.25 * 4.0))


@pytest.mark.parametrize("ratio,expect", [(1.49, False), (1.51, True)])
def test_audit_profile_threshold_boundary(ratio, expect):
    prof = _profile()
    _, updated = audit_profile(prof, 1.0, ratio, threshold=1.5, ema=0.5)
    assert updated is expect
