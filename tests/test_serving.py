"""Serving runtime: planner policies, executor accounting, ES-failure
replanning, straggler profile updates."""
import numpy as np
import pytest

from repro.core import OffloadInstance, paper_instance
from repro.serving import (ServingRuntime, TierProfile, execute, plan,
                           replan_without_es)


def _profile():
    return TierProfile(
        name="t", p_ed=np.array([[0.01, 0.04]]), p_es=np.array([0.35]),
        acc=np.array([0.4, 0.56, 0.77]), classes=[64])


def _applies(m=2):
    calls = {"ed": [], "es": []}

    def make_ed(i):
        def f(jobs):
            calls["ed"].append((i, len(jobs)))
            return [0.5] * len(jobs)
        return f

    def es(jobs):
        calls["es"].append(len(jobs))
        return [0.9] * len(jobs)

    return [make_ed(i) for i in range(m)], es, calls


def test_plan_auto_picks_amdp_for_identical():
    prof = _profile()
    inst = prof.instance(np.full(10, 64), T=1.0)
    p = plan(inst)
    assert p.policy == "amdp"
    assert p.schedule.makespan <= 1.0 + 1e-9


def test_plan_policies_agree_on_feasibility():
    inst = paper_instance(16, T=2.0, seed=0)
    for policy in ("amr2", "greedy", "dual"):
        p = plan(inst, policy=policy)
        assert len(p.schedule.assignment) == 16
        total = sum(len(v) for v in p.per_model.values())
        assert total == 16


def test_executor_runs_all_jobs():
    prof = _profile()
    inst = prof.instance(np.full(12, 64), T=0.5)
    p = plan(inst)
    apply_ed, apply_es, calls = _applies()
    jobs = list(range(12))
    rep = execute(p, apply_ed, apply_es, jobs)
    assert len(rep.results) == 12
    assert rep.wall_makespan >= 0


def test_es_failure_replans_onto_ed():
    prof = _profile()
    inst = prof.instance(np.full(12, 64), T=1.0)
    p = plan(inst)
    assert len(p.per_model[2]) > 0          # some jobs offloaded
    apply_ed, apply_es, calls = _applies()
    rep = execute(p, apply_ed, apply_es, list(range(12)), es_fail=True)
    assert rep.replanned
    assert len(calls["es"]) == 0            # ES never invoked
    assert len(rep.results) == 12           # nothing dropped


def test_replan_without_es_never_offloads():
    inst = paper_instance(10, T=4.0, seed=1)
    p = replan_without_es(inst)
    assert (p.schedule.assignment < inst.m).all()


def test_straggler_updates_profile():
    import time
    prof = _profile()
    apply_ed, apply_es, _ = _applies()

    def slow_ed(jobs):
        time.sleep(0.3)
        return [0.5] * len(jobs)

    rt = ServingRuntime(prof, [slow_ed, slow_ed], apply_es, T=0.6,
                        straggler_threshold=1.5)
    jobs = list(range(10))
    stats = rt.run_period(jobs, np.full(10, 64))
    if stats.predicted_makespan > 0 and \
            (rt.profile.p_ed > prof.p_ed).any():
        assert stats.profile_updated
    # a second period plans with the updated (slower) profile
    stats2 = rt.run_period(jobs, np.full(10, 64))
    assert stats2.n_jobs == 10


def test_dual_schedule_feasible_and_close_to_amr2():
    from repro.core import amr2, dual_schedule
    for seed in range(5):
        inst = paper_instance(48, T=3.0, seed=seed)
        d = dual_schedule(inst)
        a = amr2(inst)
        assert d.violation == 0.0            # dual is strictly T-feasible
        assert d.total_accuracy >= 0.85 * a.total_accuracy
