"""End-to-end fault tolerance: the training driver is preempted mid-run,
resumes from the published checkpoint, and reaches a bit-identical state
versus an uninterrupted run (deterministic pipeline + saved optimizer)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _run(args, timeout=560):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mamba2-130m",
         "--smoke", "--global-batch", "4", "--seq", "32"] + args,
        env=env, capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_preempt_resume_matches_uninterrupted(tmp_path):
    d1 = str(tmp_path / "cont")
    d2 = str(tmp_path / "interrupted")

    # uninterrupted 8-step run
    r = _run(["--steps", "8", "--ckpt-dir", d1, "--ckpt-every", "3",
              "--seed", "5"])
    assert r.returncode == 0, r.stdout + r.stderr

    # interrupted run: preempt immediately via sentinel after step ~0
    sentinel = str(tmp_path / "PREEMPT")
    open(sentinel, "w").close()
    r = _run(["--steps", "8", "--ckpt-dir", d2, "--ckpt-every", "3",
              "--seed", "5", "--preempt-file", sentinel])
    assert r.returncode == 42          # preempted + saved
    os.remove(sentinel)

    # resume to completion
    r = _run(["--steps", "8", "--ckpt-dir", d2, "--ckpt-every", "3",
              "--seed", "5", "--resume"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "resumed from step" in r.stdout

    # final checkpoints agree bit-for-bit (params leaf 0)
    from repro.checkpoint import manager as ckpt
    s1, s2 = ckpt.latest_step(d1), ckpt.latest_step(d2)
    assert s1 == s2 == 7
    a = np.load(os.path.join(d1, f"step_{s1:09d}", "leaf_00000.npy"))
    b = np.load(os.path.join(d2, f"step_{s2:09d}", "leaf_00000.npy"))
    np.testing.assert_array_equal(a, b)
