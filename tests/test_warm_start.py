"""Warm-started LP through the api front door + solver-status surfacing:
`Solution.basis` round-trips, `solve(..., warm_start=)` matches the cold
solve, `strict=` raises-or-warns on unsolved statuses, and the fleet
engine carries per-device bases across periods."""
import warnings

import numpy as np
import pytest

from repro import api
from repro.core import InstanceBatch, identical_instance, random_instance
from repro.core.problem import ST_UNSOLVED

B, N, M = 6, 8, 2
T = 1.2


def _fleet(seed=0):
    insts = [random_instance(N, M, T=T, seed=seed + s) for s in range(B)]
    return api.FleetProblem.from_batch(InstanceBatch.stack(insts))


# ---------------------------------------------------------------------------
# Solution.basis + warm_start round-trips
# ---------------------------------------------------------------------------
def test_fleet_solution_carries_basis():
    sol = api.solve(_fleet(), policy="amr2")
    assert sol.basis is not None
    assert sol.basis.shape == (B, N + 2)        # 2 budget rows + n eq rows
    assert (sol.basis >= 0).all()


@pytest.mark.parametrize("policy", ["amr2", "lp"])
def test_warm_start_fleet_matches_cold(policy):
    fp = _fleet(seed=10)
    cold = api.solve(fp, policy=policy)
    warm = api.solve(fp, policy=policy, warm_start=cold.basis)
    np.testing.assert_allclose(np.atleast_1d(warm.accuracy),
                               np.atleast_1d(cold.accuracy), atol=1e-9)
    np.testing.assert_array_equal(warm.status, cold.status)
    assert warm.basis is not None


def test_warm_start_single_problem_matches_cold():
    p = api.Problem.from_instance(random_instance(N, M, T=T, seed=3))
    cold = api.solve(p, policy="amr2")
    assert cold.basis is not None
    warm = api.solve(p, policy="amr2", warm_start=cold.basis)
    assert warm.accuracy == pytest.approx(cold.accuracy, abs=1e-9)
    np.testing.assert_array_equal(warm.assignment, cold.assignment)


def test_warm_start_auto_split_slices_rows():
    """auto dispatch: identical-job devices go to the DP (no basis), the
    rest warm-start AMR² from their sliced basis rows."""
    insts = [identical_instance(N, M, T=1.0, seed=0),
             random_instance(N, M, T=T, seed=1),
             random_instance(N, M, T=T, seed=2)]
    fp = api.FleetProblem.from_batch(InstanceBatch.stack(insts))
    cold = api.solve(fp, policy="auto")
    assert cold.basis is not None
    assert (cold.basis[0] == -1).all()          # amdp row: no LP basis
    assert (cold.basis[1:] >= 0).all()
    warm = api.solve(fp, policy="auto", warm_start=cold.basis)
    np.testing.assert_allclose(warm.accuracy, cold.accuracy, atol=1e-9)
    np.testing.assert_array_equal(warm.assignment, cold.assignment)


def test_warm_start_rejected_for_non_lp_policy():
    fp = _fleet()
    basis = api.solve(fp, policy="amr2").basis
    with pytest.raises(TypeError, match="warm_start"):
        api.solve(fp, policy="dual", warm_start=basis)


def test_solve_many_warm_start_alignment():
    probs = [api.Problem.from_instance(random_instance(N, M, T=T, seed=s))
             for s in range(4)]
    cold = api.solve_many(probs, policy="amr2")
    bases = [s.basis for s in cold]
    assert all(b is not None for b in bases)
    warm = api.solve_many(probs, policy="amr2", warm_start=bases)
    for w, c in zip(warm, cold):
        assert w.accuracy == pytest.approx(c.accuracy, abs=1e-9)
    # mixed None entries are fine (those members solve cold)
    warm2 = api.solve_many(probs, policy="amr2",
                           warm_start=[bases[0], None, bases[2], None])
    for w, c in zip(warm2, cold):
        assert w.accuracy == pytest.approx(c.accuracy, abs=1e-9)
    with pytest.raises(ValueError, match="align"):
        api.solve_many(probs, policy="amr2", warm_start=bases[:2])


def test_warm_start_numpy_backend_fleet():
    """The sequential oracle path warm-starts per device (and skips -1
    rows) — parity with the cold sequential solve."""
    fp = _fleet(seed=20)
    cold = api.solve(fp, policy="amr2", backend="numpy")
    wb = cold.basis.copy()
    wb[0] = -1                                  # device 0: cold re-solve
    warm = api.solve(fp, policy="amr2", backend="numpy", warm_start=wb)
    np.testing.assert_allclose(warm.accuracy, cold.accuracy, atol=1e-9)


# ---------------------------------------------------------------------------
# solver-status surfacing: strict= raise-or-warn on unsolved
# ---------------------------------------------------------------------------
def test_tiny_maxiter_strict_raises_fleet():
    with pytest.raises(RuntimeError, match="unsolved"):
        api.solve(_fleet(), policy="amr2", maxiter=1)


def test_tiny_maxiter_strict_raises_single():
    p = api.Problem.from_instance(random_instance(N, M, T=T, seed=5))
    with pytest.raises(RuntimeError, match="unsolved"):
        api.solve(p, policy="amr2", maxiter=1)


def test_tiny_maxiter_nonstrict_warns_and_marks():
    fp = _fleet()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        sol = api.solve(fp, policy="amr2", maxiter=1, strict=False)
    assert any("unsolved" in str(w.message) for w in caught)
    assert (np.asarray(sol.status) == ST_UNSOLVED).all()
    assert set(np.atleast_1d(sol.status_name)) == {"unsolved"}
    assert np.isnan(sol.lp_accuracy).all()      # no valid bound


def test_sane_maxiter_never_marks():
    sol = api.solve(_fleet(), policy="amr2")    # default budget
    assert not (np.asarray(sol.status) == ST_UNSOLVED).any()


def test_core_amr2_raises_by_default_on_cap():
    """Direct core calls (no front door) keep the fail-loud default."""
    from repro.core import amr2
    inst = random_instance(N, M, T=T, seed=6)
    with pytest.raises(RuntimeError, match="did not converge"):
        amr2(inst, maxiter=1)


# ---------------------------------------------------------------------------
# fleet engine: per-device bases across periods
# ---------------------------------------------------------------------------
def _engines(policy="amr2", n=6, seed=3):
    from repro.serving import FleetEngine, RequestQueue
    from repro.serving.fleet import make_fleet

    def build():
        specs = make_fleet(n, seed=seed, horizon=8)
        q = RequestQueue(n, (128, 512, 1024), rate=8.0, batch_max=8,
                         seed=seed)
        return FleetEngine(specs, q, n_servers=1, T=T, backend="jax",
                           policy=policy)
    return build(), build()


def test_engine_stores_and_reuses_warm_bases():
    warm_eng, cold_eng = _engines()
    for _ in range(3):
        sw = warm_eng.run_period()
        for g in cold_eng._groups:          # twin with warm state wiped
            g.warm_basis = None
        sc = cold_eng.run_period()
        assert sw.total_accuracy == pytest.approx(sc.total_accuracy,
                                                  abs=1e-9)
        assert sw.n_backpressured == sc.n_backpressured
        assert sw.n_offloading == sc.n_offloading
    assert all(g.warm_basis is not None for g in warm_eng._groups)
    assert all((g.warm_basis >= 0).all() for g in warm_eng._groups)


def test_engine_dual_policy_keeps_no_basis():
    warm_eng, _ = _engines(policy="dual")
    warm_eng.run(2)
    assert all(g.warm_basis is None for g in warm_eng._groups)


# ---------------------------------------------------------------------------
# stale-basis invalidation on outage flips (host engine, both period paths)
# ---------------------------------------------------------------------------
def _flip_engine(*, delegate=True, n=8, seed=11):
    """A fleet with aggressive ES outage schedules so flips are frequent."""
    from repro.serving import FleetEngine, RequestQueue
    from repro.serving.fleet import make_fleet
    specs = make_fleet(n, seed=seed, horizon=8, outage_frac=0.9)
    q = RequestQueue(n, (128, 512, 1024), rate=8.0, batch_max=8, seed=seed)
    return FleetEngine(specs, q, n_servers=2, T=T, backend="jax",
                       policy="amr2", delegate=delegate)


def test_v2_period_cold_starts_stale_bases_on_outage_flip(monkeypatch):
    """Regression: a device whose ES outage state flipped since last
    period must reach the jitted period core with warm rows -1 (the
    carried basis labels an LP whose offload columns no longer exist) —
    while unflipped devices keep their carry."""
    from repro.api import engine as E
    eng = _flip_engine(delegate=True)
    assert eng._v2_params is not None
    real = E._period_jit
    seen = []

    def spy(belief, warm, *a, **k):
        seen.append(np.asarray(warm).copy())
        return real(belief, warm, *a, **k)

    monkeypatch.setattr(E, "_period_jit", spy)
    periods = 6
    eng.run(periods)
    flips = kept = 0
    for t in range(1, periods):
        for d, st in enumerate(eng.devices):
            if st.spec.outage_at(t) != st.spec.outage_at(t - 1):
                flips += 1
                assert (seen[t][d] == -1).all(), (t, d)
            elif (seen[t][d] >= 0).any():
                kept += 1
    assert flips > 0         # the schedule actually exercised the edge
    assert kept > 0          # and unflipped devices still warm-start


def test_host_period_cold_starts_stale_bases_on_outage_flip(monkeypatch):
    """Same regression on the pre-v2 host pipeline (`delegate=False`):
    the warm_start array handed to `api.solve` must have -1 rows exactly
    where the outage state flipped."""
    import repro.serving.fleet as fleet_mod
    eng = _flip_engine(delegate=False)
    assert eng._v2_params is None
    real = fleet_mod.solve
    seen = []

    def spy(fp, **kw):
        seen.append(None if kw.get("warm_start") is None
                    else np.asarray(kw["warm_start"]).copy())
        return real(fp, **kw)

    monkeypatch.setattr(fleet_mod, "solve", spy)
    periods = 5
    eng.run(periods)
    # one solve per period (single shape group, plus any fallback solves
    # which pass no warm_start): pick out the per-period group solves
    group_calls = [w for w in seen if w is not None]
    assert len(group_calls) >= periods - 1
    flips = 0
    for t in range(1, periods):
        warm = group_calls[t - 1]        # t=0 passes no warm_start
        for d, st in enumerate(eng.devices):
            if st.spec.outage_at(t) != st.spec.outage_at(t - 1):
                flips += 1
                assert (warm[d] == -1).all(), (t, d)
    assert flips > 0


def test_host_period_drops_basis_when_solver_returns_none(monkeypatch):
    """If a period's solve returns no basis (e.g. the policy dispatched
    every lane to a non-LP solver), the group's warm carry must become
    None — not survive as a stale array for a later LP period."""
    import repro.serving.fleet as fleet_mod
    eng = _flip_engine(delegate=False)
    eng.run_period()
    assert eng._groups[0].warm_basis is not None
    real = fleet_mod.solve

    def strip_basis(fp, **kw):
        sol = real(fp, **kw)
        sol.basis = None
        return sol

    monkeypatch.setattr(fleet_mod, "solve", strip_basis)
    eng.run_period()
    assert eng._groups[0].warm_basis is None
    monkeypatch.undo()
    eng.run_period()          # and the next LP period runs cold, cleanly
    assert eng._groups[0].warm_basis is not None
