"""The batched solvers run under LOCAL `jax.experimental.enable_x64` scopes
so their results are float64 regardless of the process-global
``jax_enable_x64`` flag.  Toggling the global flag mid-process must neither
change results nor trip stale-trace / dtype-mismatch errors — the jit
caches key on the traced avals (f64 inside the scope either way), and this
file pins that contract by solving the same instances with the flag off
and on in one process."""
import jax
import numpy as np
import pytest

from repro.core import InstanceBatch, random_instance, solve_lp_batch
from repro.core.amr2 import build_lp_arrays_batch
from repro.core.dual import dual_schedule_batch_arrays

B, N, M = 5, 8, 2


def _batch(seed=0):
    return InstanceBatch.stack(
        [random_instance(N, M, T=1.2, seed=seed + s) for s in range(B)])


def _lp_inputs(batch):
    return build_lp_arrays_batch(batch)


@pytest.fixture
def x64_toggle():
    """Restore the global flag no matter how the test exits."""
    prev = jax.config.jax_enable_x64
    yield
    jax.config.update("jax_enable_x64", prev)


def test_solve_lp_batch_invariant_to_global_x64(x64_toggle):
    batch = _batch(0)
    c, A_ub, b_ub, A_eq, b_eq = _lp_inputs(batch)
    res_off = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    warm_off = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq,
                              warm_basis=res_off.basis)

    jax.config.update("jax_enable_x64", True)
    res_on = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    warm_on = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq,
                             warm_basis=res_off.basis)

    np.testing.assert_array_equal(res_on.status, res_off.status)
    np.testing.assert_array_equal(res_on.niter, res_off.niter)
    np.testing.assert_array_equal(res_on.basis, res_off.basis)
    np.testing.assert_array_equal(res_on.x, res_off.x)      # bit parity
    np.testing.assert_array_equal(res_on.fun, res_off.fun)
    np.testing.assert_array_equal(warm_on.warm, warm_off.warm)
    np.testing.assert_array_equal(warm_on.x, warm_off.x)

    jax.config.update("jax_enable_x64", False)              # and back again
    res_off2 = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    np.testing.assert_array_equal(res_off2.x, res_off.x)


def test_dual_schedule_batch_invariant_to_global_x64(x64_toggle):
    batch = _batch(10)
    assign_off, status_off = dual_schedule_batch_arrays(batch)

    jax.config.update("jax_enable_x64", True)
    assign_on, status_on = dual_schedule_batch_arrays(batch)

    np.testing.assert_array_equal(assign_on, assign_off)
    np.testing.assert_array_equal(status_on, status_off)

    jax.config.update("jax_enable_x64", False)
    assign_off2, _ = dual_schedule_batch_arrays(batch)
    np.testing.assert_array_equal(assign_off2, assign_off)


def test_both_solvers_interleaved_under_toggles(x64_toggle):
    """Interleave LP and dual solves across three flag states in one
    process — the scenario that would surface a stale-trace/dtype bug."""
    batch = _batch(20)
    c, A_ub, b_ub, A_eq, b_eq = _lp_inputs(batch)
    ref_lp = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
    ref_dual = dual_schedule_batch_arrays(batch)

    for flag in (True, False, True):
        jax.config.update("jax_enable_x64", flag)
        got_lp = solve_lp_batch(c, A_ub, b_ub, A_eq, b_eq)
        got_dual = dual_schedule_batch_arrays(batch)
        np.testing.assert_array_equal(got_lp.x, ref_lp.x)
        np.testing.assert_array_equal(got_dual[0], ref_dual[0])


def test_engine_f32_guard_under_global_x64_off():
    """The engine's float64 guard, end to end in a FRESH interpreter with
    the global x64 flag off: a `device_put` of the state outside any
    `enable_x64` scope silently materializes float32 buffers, and
    `engine.step` must refuse them with a TypeError naming the leaf (the
    old behavior ran the whole rollout at single precision, quietly
    voiding the documented parity claims)."""
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import jax
        assert not jax.config.jax_enable_x64
        import numpy as np
        from repro.api import engine as E
        from repro.serving import FleetConfig

        cfg = FleetConfig(n_devices=4, T=1.2, n_servers=1, policy="amr2",
                          backend="jax", rate=6.0, batch_max=8,
                          horizon=6, seed=0)
        params = E.EngineParams.from_config(cfg, horizon=6)
        state = E.init_state(params)
        # the buggy pattern: an unscoped transfer downcasts to f32
        bad = jax.tree.map(jax.device_put, state)
        assert np.asarray(bad.p_ed).dtype == np.float32
        try:
            E.step(bad, params)
        except TypeError as e:
            assert "state.p_ed" in str(e) and "float32" in str(e), e
            print("GUARDED")
        else:
            raise SystemExit("f32 state was accepted silently")

        # the correct pattern still works: scoped transfers stay f64
        from jax.experimental import enable_x64
        with enable_x64():
            good = jax.tree.map(jax.device_put, state)
        st2, m = E.step(good, params)
        assert np.asarray(st2.p_ed).dtype == np.float64
        print("OK")
    """)
    env = dict(os.environ)
    env.pop("JAX_ENABLE_X64", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "GUARDED" in out.stdout and "OK" in out.stdout
